"""Chaos layer for the distributed tile driver (``core.dist_exec``).

Every fault here is DETERMINISTIC: ``InjectedFault(tile, worker,
attempt)`` fires exactly when that tile lands on that worker on that
attempt, and all timing flows through the serving layer's ``FakeClock``
— there are NO wall-clock sleeps anywhere in this file. The contract
under test (DESIGN.md §10):

* a failed dispatch retries on a DIFFERENT surviving worker;
* a killed worker drops out mid-run (elastic re-plan onto the shrunken
  set) and the run still completes **bit-identical to numpy**;
* a worker exceeding ``worker_fail_limit`` failures is dropped like a
  kill;
* terminal failures carry machine-readable reasons
  (``"retries-exhausted"`` / ``"no-workers"``), per-dispatch failures
  log reasons (``"injected-fail"`` / ``"injected-kill"`` /
  ``"tile-timeout"``) mirroring ``AdmissionError.reason``;
* injected slowness trips the timeout detector and the straggler
  watchdog without any real elapsed time.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.dist_exec import (DistributedError, DistTiledExpr,
                                  FaultInjector, InjectedFault,
                                  dist_compile)
from repro.core.jax_backend import compile_expr
from repro.core.schedule import Format, Schedule
from repro.core.serving import FakeClock
from repro.distributed.fault_tolerance import StragglerPolicy

EXPR = "X(i,j) = B(i,k) * C(k,j)"
FMT = Format({"B": "cc", "C": "cc"})
SCH = Schedule(loop_order=("i", "k", "j"), tile={"i": 2, "k": 2})  # 4 tiles
N = 8
DIMS = {"i": N, "j": N, "k": N}


def _operands(seed: int = 0):
    """Integer-valued operands: f32 partial sums are exact, so equality
    checks are bitwise, not tolerances."""
    rng = np.random.default_rng(seed)
    B = ((rng.random((N, N)) < 0.4)
         * rng.integers(1, 9, (N, N))).astype(float)
    C = ((rng.random((N, N)) < 0.4)
         * rng.integers(1, 9, (N, N))).astype(float)
    return {"B": B, "C": C}


def _dist(faults=(), **kw):
    kw.setdefault("clock", FakeClock())
    return dist_compile(EXPR, FMT, SCH, DIMS, faults=list(faults), **kw)


def test_injected_fail_retries_on_surviving_worker():
    # inline schedule: tile 1 attempt 0 -> worker (1+0) % 2 = 1; the
    # injected fail forces attempt 1 -> worker (1+1) % 2 = 0
    arrays = _operands()
    want = arrays["B"] @ arrays["C"]
    d = _dist([InjectedFault(tile=1, worker=1, attempt=0, kind="fail")],
              workers=2, overlap=False)
    out = d(arrays).to_dense()
    assert np.array_equal(out, want)
    assert d.stats["failures"] == 1 and d.stats["retries"] == 1
    assert d.stats["workers_lost"] == 0
    assert d.live_workers == [0, 1]          # a fail does NOT kill
    assert d.failure_log == [{"tile": 1, "worker": 1, "attempt": 0,
                              "reason": "injected-fail",
                              "worker_lost": False}]
    assert [(f.tile, f.worker) for f in d.faults.fired] == [(1, 1)]


def test_kill_one_worker_mid_run_bit_identical():
    # the ROADMAP acceptance bar: threaded fan-out over 2 workers, kill
    # worker 1 on its first tile; its queued tiles orphan back to the
    # survivor and the result bytes still equal numpy AND the
    # single-device tiled fold
    arrays = _operands(seed=1)
    want = arrays["B"] @ arrays["C"]
    ref = compile_expr(EXPR, FMT, SCH, DIMS)(arrays).to_dense()
    d = _dist([InjectedFault(tile=1, worker=1, attempt=0, kind="kill")],
              workers=2, overlap=True)
    out = d(arrays).to_dense()
    assert out.tobytes() == ref.tobytes()
    assert np.array_equal(out, want)
    assert d.stats["workers_lost"] == 1 and d.stats["replans"] == 1
    assert d.stats["retries"] == 1
    assert d.live_workers == [0]
    [entry] = d.failure_log
    assert entry["reason"] == "injected-kill" and entry["worker_lost"]
    # all 4 tiles completed somewhere, none lost
    assert sum(w.tiles_done for w in d.workers) == 4

    # revive() restores the full fabric; with the chaos hooks swapped
    # out the next run is clean (faults persist per-injector, so a
    # revived fabric under the SAME injector would die again)
    d.revive()
    d.faults = FaultInjector()
    assert d.live_workers == [0, 1]
    assert np.array_equal(d(arrays).to_dense(), want)
    assert d.stats["workers_lost"] == 1      # history, not state


def test_fail_limit_drops_flaky_worker():
    # worker_fail_limit=0: the very first failure exceeds the limit and
    # the worker is dropped exactly like a kill
    arrays = _operands(seed=2)
    d = _dist([InjectedFault(tile=1, worker=1, attempt=0, kind="fail")],
              workers=2, overlap=False, worker_fail_limit=0)
    out = d(arrays).to_dense()
    assert np.array_equal(out, arrays["B"] @ arrays["C"])
    assert d.stats["workers_lost"] == 1
    assert d.live_workers == [0]
    assert d.failure_log[0]["worker_lost"]


def test_retries_exhausted_is_machine_readable():
    # tile 1 fails on every attempt (attempt 0 on worker 1, attempt 1 on
    # worker 0); max_attempts=2 makes the second failure terminal
    arrays = _operands(seed=3)
    d = _dist([InjectedFault(tile=1, worker=1, attempt=0),
               InjectedFault(tile=1, worker=0, attempt=1)],
              workers=2, overlap=False, max_attempts=2)
    with pytest.raises(DistributedError) as ei:
        d(arrays)
    assert ei.value.reason == "retries-exhausted"
    assert [e["reason"] for e in d.failure_log] == ["injected-fail"] * 2


def test_all_workers_lost_is_machine_readable():
    arrays = _operands(seed=4)
    d = _dist([InjectedFault(tile=0, worker=0, attempt=0, kind="kill")],
              workers=1)
    with pytest.raises(DistributedError) as ei:
        d(arrays)
    assert ei.value.reason == "no-workers"
    # a driver whose whole fabric died refuses further calls until
    # revive()
    with pytest.raises(DistributedError) as ei2:
        d(arrays)
    assert ei2.value.reason == "no-workers"
    d.revive()
    d.faults = FaultInjector()
    assert np.array_equal(d(arrays).to_dense(),
                          arrays["B"] @ arrays["C"])


def test_slow_fault_trips_timeout_and_retries():
    # the slow fault advances the INJECTED clock by 10s (> 5s timeout):
    # detected as a tile-timeout failure, retried on the other worker —
    # zero wall-clock time passes
    arrays = _operands(seed=5)
    d = _dist([InjectedFault(tile=0, worker=0, attempt=0, kind="slow",
                             dt=10.0)],
              workers=2, overlap=False, tile_timeout_s=5.0)
    out = d(arrays).to_dense()
    assert np.array_equal(out, arrays["B"] @ arrays["C"])
    assert d.stats["timeouts"] == 1 and d.stats["retries"] == 1
    assert d.failure_log[0]["reason"] == "tile-timeout"


def test_straggler_watchdog_flags_injected_slowness():
    # on the FakeClock every normal tile takes 0s, so the EMA settles at
    # 0 and ANY injected slowness (under the 5s timeout here) flags as a
    # straggler without failing the tile
    arrays = _operands(seed=6)
    pol = StragglerPolicy(threshold=2.0, grace_steps=0)
    d = _dist([InjectedFault(tile=3, worker=1, attempt=0, kind="slow",
                             dt=1.0)],
              workers=2, overlap=False, tile_timeout_s=5.0,
              straggler=pol)
    out = d(arrays).to_dense()
    assert np.array_equal(out, arrays["B"] @ arrays["C"])
    assert d.stats["stragglers"] == 1 and d.stats["timeouts"] == 0
    [(step, dt, _ema)] = pol.flagged
    assert step == 3 and dt == 1.0
    assert d.stats["failures"] == 0          # flagged, not failed


def test_fault_validation_and_injector_bookkeeping():
    with pytest.raises(ValueError):
        InjectedFault(tile=0, worker=0, kind="meteor")
    inj = FaultInjector([InjectedFault(tile=2, worker=0, attempt=1)])
    assert inj.check(2, 0, 0) is None        # wrong attempt: no fire
    assert inj.check(2, 0, 1) is not None
    assert len(inj.fired) == 1
    arrays = _operands(seed=7)
    d = _dist([], workers=2, overlap=False)
    assert np.array_equal(d(arrays).to_dense(),
                          arrays["B"] @ arrays["C"])
    assert d.stats["failures"] == 0 and d.faults.fired == []


def test_dist_requires_a_tiled_engine():
    plain = compile_expr(EXPR, FMT, Schedule(loop_order=("i", "k", "j")),
                         DIMS)
    with pytest.raises(TypeError):
        DistTiledExpr(plain)
    with pytest.raises(ValueError):
        dist_compile(EXPR, FMT, Schedule(loop_order=("i", "k", "j")),
                     DIMS)
