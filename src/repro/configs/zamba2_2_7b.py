"""zamba2-2.7b [hybrid]: 54L(mamba2) d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64 - Mamba2 backbone + ONE shared attention+MLP
block applied every 6 mamba layers [arXiv:2411.15242; hf]. (Zamba2 uses two
alternating shared blocks; we model one, noted in DESIGN.md.) Runs
long_500k: mamba state is O(1), shared attention KV is seq-sharded."""
import dataclasses
from .base import ModelConfig, register

CFG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, attn_every=6,
    ssm_chunk=64)

REDUCED = dataclasses.replace(
    CFG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256, head_dim=16, attn_every=2, ssm_headdim=16, ssm_state=16)

register(CFG, REDUCED)
