"""Producer→consumer program fusion: fused SDDMM→SpMM vs the unfused
two-expression path (paper §6 / FuseFlow).

The program::

    T(i,j) = B(i,j) * C(i,k) * D(j,k)      # SDDMM  (order ijk)
    A(i,j) = T(i,k) * E(k,j)               # SpMM   (order ikj, Gustavson)

is executed two ways:

* **fused** — ``compile_program``: one jitted cascade; ``T``'s keyed COO
  result converts to on-device ``(seg, crd)`` levels that the SpMM
  scanners read directly, never leaving the accelerator. The simulator
  counterpart splices the SDDMM writer streams over the SpMM scanners
  and extends the steady-state law across the whole pipeline.
* **unfused** — the status-quo two-call path: ``compile_expr`` per
  expression with a full fibertree materialize + dense re-scan between
  (exactly what every chained workload paid before the program layer).

Reported (CSV: mode,cycles,wall_us,derived):

* **model_speedup** — unfused total simulator cycles (the two pipelines
  run back to back) over the fused stitched pipeline's cycles.
* **wall_speedup**  — measured warm wall-clock per request, unfused over
  fused (medians over ``reps`` dispatches).

Both must clear the 1.3x acceptance threshold AND the two paths must
produce bit-identical results; the bench fails otherwise. In ``--smoke``
mode only the (deterministic) cycle model and bit-identity gate — like
``split_scaling``, sub-10ms wall clocks on a shared CI core are too
noisy to gate on, so the wall ratio is reported unguarded.

    PYTHONPATH=src python -m benchmarks.run program_fusion
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.jax_backend import compile_expr, compile_program
from repro.core.program import numpy_reference, simulate_program
from repro.core.schedule import Format, Schedule

from .common import RNG, uniform_sparse

PROGRAM = ("T(i,j) = B(i,j) * C(i,k) * D(j,k); "
           "A(i,j) = T(i,k) * E(k,j)")
SCHEDULES = {"T": Schedule(loop_order=("i", "j", "k")),
             "A": Schedule(loop_order=("i", "k", "j"))}
FMT = Format(default="c")


def _best_call_us(fn, reps: int) -> float:
    """Minimum per-call wall time: the noise-immune capability measure
    (GC pauses and scheduler jitter only ever ADD time, identically to
    both paths, so comparing minima compares the paths themselves)."""
    fn()                               # warm: plan + trace already paid
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.min(times)) * 1e6


def run(log, smoke: bool = False) -> bool:
    n = 24 if smoke else 32
    density = 0.2
    reps = 5 if smoke else 25
    threshold = 1.3
    dims = {"i": n, "j": n, "k": n}
    arrays = {t: uniform_sparse((n, n), density, RNG)
              for t in ("B", "C", "D", "E")}
    want = numpy_reference(PROGRAM, arrays)["A"]

    # modeled cycles: stitched pipeline vs materialize-then-rescan
    fused_sim = simulate_program(PROGRAM, FMT, SCHEDULES, dims, arrays)
    unfused_sim = simulate_program(PROGRAM, FMT, SCHEDULES, dims, arrays,
                                   fuse=False)
    assert all(d.fused for d in fused_sim.decisions), fused_sim.decisions
    ok = bool(np.allclose(fused_sim.dense["A"], want)
              and np.allclose(unfused_sim.dense["A"], want))
    model = unfused_sim.cycles / fused_sim.cycles

    # engine wall time: one fused cascade vs the literal two-call path
    prog = compile_program(PROGRAM, FMT, SCHEDULES, dims)
    e_sddmm = compile_expr("T(i,j) = B(i,j) * C(i,k) * D(j,k)", FMT,
                           SCHEDULES["T"], dims)
    e_spmm = compile_expr("A(i,j) = T(i,k) * E(k,j)", FMT,
                          SCHEDULES["A"], dims)

    def fused_call():
        return prog(arrays)["A"]

    def unfused_call():
        t_ft = e_sddmm(arrays)                       # materialize T ...
        return e_spmm({"T": t_ft.to_dense(),         # ... and re-scan it
                       "E": arrays["E"]})

    fused_out = fused_call().to_dense()
    unfused_out = unfused_call().to_dense()
    identical = bool(np.array_equal(fused_out, unfused_out))
    ok &= identical and bool(np.allclose(fused_out, want))
    fused_us = _best_call_us(fused_call, reps)
    unfused_us = _best_call_us(unfused_call, reps)
    wall = unfused_us / fused_us

    log("program_fusion/header,mode,cycles,wall_us,derived")
    log(f"program_fusion,fused,{fused_sim.cycles},{fused_us:.0f},"
        f"{'pass' if ok else 'FAIL'}")
    log(f"program_fusion,unfused,{unfused_sim.cycles},{unfused_us:.0f},"
        f"{'bit-identical' if identical else 'MISMATCH'}")
    ok &= model >= threshold
    if not smoke:                      # wall gates at full size only
        ok &= wall >= threshold
    log(f"program_fusion/summary,model_speedup,{model:.2f},"
        f"wall_speedup,{wall:.2f}{'(unguarded)' if smoke else ''},"
        f"threshold,{threshold}")
    return ok
