"""Fuzz ``coord_ops.accumulate_coo`` under adversarial partial arrival
orders — the primitive the distributed tile merge rests on.

The distributed driver (``core.dist_exec``) folds per-worker COO
partials through ``accumulate_coo`` in tile-grid order; bit-identical
results rely on the fold being a well-behaved monoid over keyed
partials:

* **order-independence of the SET**: folding the same partials in any
  arrival order yields the same sorted (keys, vals) — integer-valued
  floats make the f32 sums exact, so this is equality, not tolerance
  (reduce-merge: overlapping key spaces, like contraction tiles;
  concat-merge: disjoint key spaces, like result tiles — both come out
  of the same primitive);
* **empty partials are identity elements** anywhere in the fold;
* **duplicate-coordinate collisions** inside ONE partial collapse into
  their sum (a partial that double-reports a coordinate);
* the dense-scatter path (``key_bound``) and the sort-merge path
  (``key_bound=None``) agree entry-for-entry.

Runs under hypothesis when present, else the deterministic
``_hypothesis_stub`` fallback.
"""
from __future__ import annotations

import numpy as np

try:
    from hypothesis import given, settings, strategies as hst
except ImportError:  # clean checkout: deterministic stub keeps tests running
    from _hypothesis_stub import given, settings, strategies as hst

from repro.core.coord_ops import accumulate_coo

KEY_BOUND = 64          # small key space forces collisions across partials


def _oracle(partials):
    """Dense reference: scatter-add every partial into a key_bound-wide
    dense vector (exact for integer-valued f32)."""
    dense = np.zeros(KEY_BOUND, np.float64)
    for keys, vals in partials:
        np.add.at(dense, keys, vals)
    live = np.nonzero(dense)[0]
    return live.astype(np.int64), dense[live].astype(np.float32)


def _fold(partials, key_bound=None):
    acc_k = np.zeros(0, np.int64)
    acc_v = np.zeros(0, np.float32)
    for keys, vals in partials:
        acc_k, acc_v = accumulate_coo(acc_k, acc_v, keys, vals,
                                      key_bound=key_bound)
    return acc_k, acc_v


@hst.composite
def partial_set(draw):
    """3-6 partials; each 0-12 entries of integer-valued floats. Key
    spaces overlap (reduce-merge) or sit in disjoint stripes
    (concat-merge) per draw; some partials are empty; some contain
    within-partial duplicate keys."""
    n_parts = draw(hst.integers(3, 6))
    disjoint = draw(hst.integers(0, 1))     # 1 -> concat-merge stripes
    stripe = KEY_BOUND // n_parts
    partials = []
    for p in range(n_parts):
        n = draw(hst.integers(0, 12))
        lo, hi = ((p * stripe, (p + 1) * stripe) if disjoint
                  else (0, KEY_BOUND))
        keys = np.array([draw(hst.integers(lo, hi - 1))
                         for _ in range(n)], np.int64)
        # small signed integers: collisions can cancel to exact zero,
        # which the oracle then drops — the merge must drop it too or
        # keep an explicit zero consistently (assert below allows both)
        vals = np.array([float(draw(hst.integers(1, 9)))
                         for _ in range(n)], np.float32)
        partials.append((keys, vals))
    perm_seed = draw(hst.integers(0, 2 ** 31 - 1))
    return partials, perm_seed


@settings(max_examples=30, deadline=None)
@given(partial_set())
def test_fold_is_arrival_order_blind(case):
    partials, perm_seed = case
    want_k, want_v = _oracle(partials)
    base_k, base_v = _fold(partials)
    assert np.array_equal(base_k, want_k)
    assert np.array_equal(base_v, want_v)
    # adversarial arrival order: any permutation folds to the same bytes
    rng = np.random.default_rng(perm_seed)
    for _ in range(3):
        order = rng.permutation(len(partials))
        got_k, got_v = _fold([partials[i] for i in order])
        assert got_k.tobytes() == base_k.tobytes()
        assert got_v.tobytes() == base_v.tobytes()


@settings(max_examples=15, deadline=None)
@given(partial_set())
def test_dense_and_sort_merge_paths_agree(case):
    partials, _ = case
    sort_k, sort_v = _fold(partials, key_bound=None)
    dense_k, dense_v = _fold(partials, key_bound=KEY_BOUND)
    assert np.array_equal(sort_k, dense_k)
    assert np.array_equal(sort_v, dense_v)


def test_empty_partials_are_identity():
    empty = (np.zeros(0, np.int64), np.zeros(0, np.float32))
    a = (np.array([3, 7], np.int64), np.array([1.0, 2.0], np.float32))
    b = (np.array([7, 9], np.int64), np.array([4.0, 8.0], np.float32))
    want_k, want_v = _oracle([a, b])
    for arrangement in ([empty, a, empty, b, empty],
                        [a, b], [empty, empty, a, b],
                        [b, empty, a]):
        got_k, got_v = _fold(arrangement)
        assert np.array_equal(got_k, want_k), arrangement
        assert np.array_equal(got_v, want_v), arrangement
    # all-empty fold: the identity itself
    k, v = _fold([empty, empty])
    assert k.size == 0 and v.size == 0


def test_within_partial_duplicate_keys_collapse():
    # one partial double-reports key 5; the merge must sum, not drop
    dup = (np.array([5, 5, 5, 2], np.int64),
           np.array([1.0, 2.0, 4.0, 3.0], np.float32))
    k, v = _fold([dup])
    assert k.tolist() == [2, 5]
    assert v.tolist() == [3.0, 7.0]
    # and colliding AGAIN with an accumulator that already holds key 5
    k2, v2 = accumulate_coo(k, v, np.array([5], np.int64),
                            np.array([10.0], np.float32))
    assert k2.tolist() == [2, 5]
    assert v2.tolist() == [3.0, 17.0]


def test_incremental_equals_one_shot():
    # folding partials one at a time == concatenating everything into a
    # single giant partial and folding once
    rng = np.random.default_rng(5)
    partials = [(rng.integers(0, KEY_BOUND, 8).astype(np.int64),
                 rng.integers(1, 9, 8).astype(np.float32))
                for _ in range(4)]
    inc_k, inc_v = _fold(partials)
    big = (np.concatenate([k for k, _ in partials]),
           np.concatenate([v for _, v in partials]))
    one_k, one_v = _fold([big])
    assert inc_k.tobytes() == one_k.tobytes()
    assert inc_v.tobytes() == one_v.tobytes()
