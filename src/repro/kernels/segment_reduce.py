"""Segment-reduce kernel: the SAM Reducer (Def 3.7) as a tiled MXU matmul.

Scatter-add has no efficient TPU primitive; the TPU-native schedule for
"sum rows with equal segment id" is a one-hot matmul: for a value tile
``V (T, D)`` and its segment ids ``s (T,)``, the contribution to the output
is ``onehot(s)^T @ V`` — an (S, T) x (T, D) MXU product. The output block
stays resident in VMEM and accumulates across value tiles.

This is the hot path of the SAM-lowered MoE combine and of the embedding
gradient (union+reduce of repeated coordinates). S (number of segments) is
bounded by the expert count / vocab tile, so the (S, D_tile) accumulator
fits VMEM comfortably.

Layout:
  vals : (N, D) float    seg_ids : (N,) int32 in [0, S)   (need not be sorted)
  out  : (S, D)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, vals_ref, o_ref, acc_ref, *, n_seg, t):
    nt = pl.program_id(1)

    @pl.when(nt == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = ids_ref[0]                          # (T,)
    seg_iota = jax.lax.broadcasted_iota(jnp.int32, (n_seg, t), 0)
    onehot = (seg_iota == ids[None, :]).astype(jnp.float32)   # (S, T)
    acc_ref[...] += jnp.dot(onehot, vals_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(nt == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "t_tile", "d_tile",
                                    "interpret"))
def segment_reduce(vals: jnp.ndarray, seg_ids: jnp.ndarray, *,
                   num_segments: int, t_tile: int = 512, d_tile: int = 128,
                   interpret: bool = False) -> jnp.ndarray:
    n, d = vals.shape
    pad_n = (-n) % t_tile
    if pad_n:
        vals = jnp.pad(vals, ((0, pad_n), (0, 0)))
        seg_ids = jnp.pad(seg_ids, (0, pad_n),
                          constant_values=num_segments)  # masked out
    pad_d = (-d) % d_tile
    if pad_d:
        vals = jnp.pad(vals, ((0, 0), (0, pad_d)))
    n_p, d_p = vals.shape
    # one extra segment swallows padding rows; dropped on return
    s_p = num_segments + 1
    ids2d = seg_ids.astype(jnp.int32).reshape(1, n_p)

    grid = (d_p // d_tile, n_p // t_tile)
    out = pl.pallas_call(
        functools.partial(_kernel, n_seg=s_p, t=t_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t_tile), lambda dj, nt: (0, nt)),
            pl.BlockSpec((t_tile, d_tile), lambda dj, nt: (nt, dj)),
        ],
        out_specs=pl.BlockSpec((s_p, d_tile), lambda dj, nt: (0, dj)),
        out_shape=jax.ShapeDtypeStruct((s_p, d_p), vals.dtype),
        scratch_shapes=[pltpu.VMEM((s_p, d_tile), jnp.float32)],
        interpret=interpret,
    )(ids2d, vals)
    return out[:num_segments, :d]
