"""Autoscheduler end-to-end: search the schedule space for a fig12-shaped
SpM*SpM, compare against every hand-written order, then serve through the
compiled engine with ``schedule="auto"`` (persistent schedule cache).

    PYTHONPATH=src python examples/autotune.py
"""
import os
import tempfile

import numpy as np

from repro.core.autoschedule import (ScheduleCache, random_operand,
                                     resolve_schedule)
from repro.core.jax_backend import compile_expr
from repro.core.schedule import Format, Schedule
from repro.core.simulator import simulate_expr

EXPR = "X(i,j) = B(i,k) * C(k,j)"
DIMS = {"i": 250, "j": 250, "k": 100}

rng = np.random.default_rng(0)
B = random_operand((250, 100), 0.05, rng)
C = random_operand((100, 250), 0.05, rng)
fmt = Format({"B": "cc", "C": "cc"})

# 1. the exhaustive baseline a user would otherwise have to guess among
print("exhaustive ijk dataflow orders (full-size simulated cycles):")
for order in ("ijk", "ikj", "jik", "jki", "kij", "kji"):
    res = simulate_expr(EXPR, fmt, Schedule(loop_order=tuple(order)),
                        {"B": B, "C": C}, DIMS)
    print(f"  {order}: {res.cycles}")

# 2. the autoscheduler: enumerate -> analytic prune -> simulate downsampled
cache = ScheduleCache(path=os.path.join(tempfile.mkdtemp(), "schedules.json"))
auto = resolve_schedule(EXPR, fmt, DIMS, arrays={"B": B, "C": C},
                        cache=cache, device_count=1)
rep = auto.report
print(f"\nautoscheduler: {rep.enumerated} schedules enumerated, "
      f"{rep.simulated} simulated at {rep.sample_dims} "
      f"in {rep.elapsed_s * 1e3:.0f}ms")
for cand in rep.candidates[:3]:
    print(f"  {cand.spec.key()}: sampled {cand.cycles} cycles")
sch = auto.schedule
full = simulate_expr(EXPR, fmt, sch, {"B": B, "C": C}, DIMS).cycles
print(f"picked order={''.join(sch.loop_order)} split={sch.split} "
      f"par={sch.parallelize}: {full} full-size cycles")

# 3. the same shape again: pure cache hit, no search
again = resolve_schedule(EXPR, fmt, DIMS, arrays={"B": B, "C": C},
                         cache=cache, device_count=1)
assert again.cache_hit and again.report is None
print("second resolution: schedule cache HIT (no search)")

# 4. serve it compiled: schedule="auto" inside the jitted engine
os.environ["SAM_SCHEDULE_CACHE"] = cache.path
eng = compile_expr(EXPR, fmt, "auto", DIMS, sparsity=0.05)
out = eng.execute({"B": B, "C": C})
assert np.allclose(out.to_dense(), B @ C)
print(f"compiled engine (auto schedule) matches B @ C; stats: {eng.stats}")
