"""Fibertree tensor data model (paper §3.1) with per-level storage formats.

A tensor is a coordinate tree: each level holds the coordinates of one
dimension; only children with nonzero sub-trees are stored. Levels are
independently assigned a storage format, described by a pluggable
``LevelSpec`` (the level-format interface of the Format Abstraction line
of work): a set of capability flags — ``full`` / ``ordered`` / ``unique``
/ ``appendable`` — plus the access methods each format supports
(``iterate`` / ``locate`` / ``insert``). The compiler consults ONLY the
flags (never the format name), so adding a format is adding a spec:

* ``dense`` (d)      — uncompressed: stores only the dimension size; every
                       coordinate is implicitly present (Fig. 3 left).
* ``compressed`` (c) — (seg, crd) arrays: segment ``[seg[r], seg[r+1])`` of
                       the coordinate array is the fiber at parent reference
                       ``r`` (Fig. 1c: DCSR when every level is compressed).
* ``bitvector`` (b)  — packed words; a set bit marks a nonempty sub-tree
                       (§4.3). Simulator-only: schedules must opt in via
                       ``Schedule.bitvector`` and the engine refuses it.
* ``singleton`` (s)  — COO-style level: one stored entry per child path,
                       duplicates across siblings NOT merged (``unique`` is
                       False). An all-``s`` tensor is classic COO.
* ``hashed`` (h)     — per-fiber open-addressed table: O(1) ``locate``, but
                       iteration yields coordinates in slot order, NOT
                       ascending (``ordered`` is False) — downstream merges
                       need an in-stream sort conversion node.
* ``bitmap`` (m)     — packed words like ``b``, but a first-class level the
                       scheduler may pick freely: scanners co-iterate it
                       word-at-a-time automatically and the engine converts
                       it on ingest.

The in-memory layout feeds the SAM level scanners; ``from_dense``/
``to_dense`` are the golden converters used throughout the tests, and
``FiberTree.convert`` re-lays a tensor under new level formats
bit-identically.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

DENSE = "dense"
COMPRESSED = "compressed"
BITVECTOR = "bitvector"
SINGLETON = "singleton"
HASHED = "hashed"
BITMAP = "bitmap"

_FORMAT_ABBREV = {"d": DENSE, "c": COMPRESSED, "b": BITVECTOR,
                  "s": SINGLETON, "h": HASHED, "m": BITMAP,
                  DENSE: DENSE, COMPRESSED: COMPRESSED, BITVECTOR: BITVECTOR,
                  SINGLETON: SINGLETON, HASHED: HASHED, BITMAP: BITMAP}

_ABBREV_OF = {DENSE: "d", COMPRESSED: "c", BITVECTOR: "b",
              SINGLETON: "s", HASHED: "h", BITMAP: "m"}

BV_WIDTH = 64  # bits per bitvector/bitmap word (paper's Fig. 13 uses b=64)


@dataclasses.dataclass(frozen=True)
class LevelSpec:
    """Capability flags + access methods of one level format.

    The flags are the level-format interface: lowering, scheduling
    legality, and the engine's ingest path branch on these — never on the
    format name — so a new format is fully described by its spec.

    * ``full``       — every coordinate in ``[0, dim)`` is implicitly
                       present (no stored coordinates).
    * ``ordered``    — ``Level.fiber`` yields ascending coordinates. An
                       unordered level needs a sort conversion node before
                       any co-iterating merge.
    * ``unique``     — at most one stored entry per (fiber, coordinate);
                       non-unique levels may fork a coordinate into several
                       sub-trees (COO duplicates) and need a tree
                       conversion before scanning.
    * ``appendable`` — the assembly path (level writers / ``from_coords``)
                       can build it.
    * ``iterate`` / ``locate`` / ``insert`` — supported access methods;
      ``locate`` admits ``Schedule.locate`` pairing and random probes.

    >>> spec_of("h").ordered, spec_of("h").locate
    (False, True)
    >>> spec_of("s").unique, spec_of("c").unique
    (False, True)
    """

    name: str
    abbrev: str
    full: bool
    ordered: bool
    unique: bool
    appendable: bool
    iterate: bool = True
    locate: bool = False
    insert: bool = False


LEVEL_SPECS = {
    DENSE: LevelSpec(DENSE, "d", full=True, ordered=True, unique=True,
                     appendable=True, locate=True, insert=True),
    COMPRESSED: LevelSpec(COMPRESSED, "c", full=False, ordered=True,
                          unique=True, appendable=True, locate=True,
                          insert=True),
    BITVECTOR: LevelSpec(BITVECTOR, "b", full=False, ordered=True,
                         unique=True, appendable=True, locate=True),
    SINGLETON: LevelSpec(SINGLETON, "s", full=False, ordered=True,
                         unique=False, appendable=True, insert=True),
    HASHED: LevelSpec(HASHED, "h", full=False, ordered=False, unique=True,
                      appendable=True, locate=True, insert=True),
    BITMAP: LevelSpec(BITMAP, "m", full=False, ordered=True, unique=True,
                      appendable=True, locate=True, insert=True),
}


def spec_of(fmt: str) -> LevelSpec:
    """Level spec for a format name or one-letter abbreviation."""
    return LEVEL_SPECS[_FORMAT_ABBREV[fmt]]


def _hash_order(crds: np.ndarray) -> np.ndarray:
    """Iteration order of a hashed fiber: ascending open-addressed slot.

    The modeled table has ``nslots`` = smallest power of two >=
    2*len(crds); coordinate ``c`` hashes to slot ``(c * 11) % nslots``
    with linear probing, inserted in ascending-coordinate order. The
    fiber iterates in ascending SLOT order — deterministic, but generally
    not ascending in coordinates (that is the whole point of the ``h``
    spec's ``ordered=False`` flag).

    >>> _hash_order(np.array([1, 2, 7])).tolist()   # slots 3, 6, 5
    [0, 2, 1]
    """
    n = len(crds)
    if n <= 1:
        return np.arange(n)
    nslots = 1
    while nslots < 2 * n:
        nslots *= 2
    slots: dict = {}
    for i in np.argsort(crds, kind="stable"):
        s = (int(crds[i]) * 11) % nslots
        while s in slots:
            s = (s + 1) % nslots
        slots[s] = int(i)
    return np.asarray([slots[s] for s in sorted(slots)], dtype=np.int64)


@dataclasses.dataclass
class Level:
    """One fibertree level in memory."""

    format: str
    dim: int                      # dense dimension size of this level
    seg: Optional[np.ndarray] = None   # compressed: segment starts, len P+1
    crd: Optional[np.ndarray] = None   # compressed: coordinates
    words: Optional[np.ndarray] = None  # bitvector: packed uint64 words (P, W)

    @property
    def spec(self) -> LevelSpec:
        return LEVEL_SPECS[self.format]

    @property
    def nnz(self) -> int:
        if self.format in (COMPRESSED, SINGLETON, HASHED):
            return int(len(self.crd))
        if self.format in (BITVECTOR, BITMAP):
            return int(sum(bin(int(w)).count("1") for w in self.words.ravel()))
        raise ValueError("dense levels have implicit coordinates")

    def fiber(self, ref: int) -> Tuple[np.ndarray, np.ndarray]:
        """(coords, child_refs) of the fiber at parent reference ``ref``.

        Coordinates come out in the format's ITERATION order: ascending
        for every ``ordered`` format, hash-slot order for ``hashed``
        (child refs still address the canonical sorted storage, so
        descendant levels are independent of the iteration order).
        """
        if self.format == DENSE:
            crds = np.arange(self.dim)
            return crds, ref * self.dim + crds
        if self.format in (COMPRESSED, SINGLETON):
            lo, hi = int(self.seg[ref]), int(self.seg[ref + 1])
            return self.crd[lo:hi], np.arange(lo, hi)
        if self.format == HASHED:
            lo, hi = int(self.seg[ref]), int(self.seg[ref + 1])
            order = _hash_order(self.crd[lo:hi])
            return self.crd[lo:hi][order], lo + order
        if self.format in (BITVECTOR, BITMAP):
            row = self.words[ref]
            crds, refs = [], []
            base = int(np.sum([bin(int(w)).count("1")
                               for r in range(ref) for w in self.words[r]]))
            count = base
            for wi, w in enumerate(row):
                w = int(w)
                for b in range(BV_WIDTH):
                    if w >> b & 1:
                        crds.append(wi * BV_WIDTH + b)
                        refs.append(count)
                        count += 1
            return np.asarray(crds, dtype=np.int64), np.asarray(refs, dtype=np.int64)
        raise ValueError(self.format)

    def sorted_fiber(self, ref: int) -> Tuple[np.ndarray, np.ndarray]:
        """Fiber in CANONICAL ascending-coordinate order (locator view).

        Identical to ``fiber`` for ordered formats; for ``hashed`` it reads
        the sorted backing storage directly, which is what an O(1) table
        probe keys on.
        """
        if self.format == HASHED:
            lo, hi = int(self.seg[ref]), int(self.seg[ref + 1])
            return self.crd[lo:hi], np.arange(lo, hi)
        return self.fiber(ref)

    def num_fibers(self) -> int:
        if self.format in (COMPRESSED, SINGLETON, HASHED):
            return len(self.seg) - 1
        if self.format in (BITVECTOR, BITMAP):
            return len(self.words)
        raise ValueError("dense levels have implicit fibers")


@dataclasses.dataclass
class FiberTree:
    """A sparse tensor: a stack of levels plus the leaf value array."""

    shape: Tuple[int, ...]
    levels: List[Level]
    vals: np.ndarray
    mode_order: Tuple[int, ...] = None  # storage order of modes (default id)

    def __post_init__(self):
        if self.mode_order is None:
            self.mode_order = tuple(range(len(self.shape)))

    @property
    def order(self) -> int:
        return len(self.shape)

    @property
    def nnz(self) -> int:
        return int(len(self.vals))

    @property
    def format_str(self) -> str:
        return "".join(_ABBREV_OF[lv.format] for lv in self.levels)

    # -- constructors -------------------------------------------------------
    @staticmethod
    def from_dense(arr: np.ndarray, formats: str | Sequence[str],
                   mode_order: Sequence[int] | None = None) -> "FiberTree":
        """Build a fibertree from a dense array.

        ``formats`` is one letter per level, e.g. ``"dc"`` (CSR), ``"cc"``
        (DCSR), ``"ss"`` (COO), ``"dm"`` (dense-over-bitmap), applied in
        ``mode_order`` (storage order; default row-major identity).
        """
        arr = np.asarray(arr)
        if arr.ndim == 0:
            return FiberTree(shape=(), levels=[],
                             vals=arr.reshape(1).astype(np.float64))
        if mode_order is not None:
            arr = np.transpose(arr, mode_order)
        else:
            mode_order = tuple(range(arr.ndim))
        fmts = [_FORMAT_ABBREV[f] for f in formats]
        if len(fmts) != arr.ndim:
            raise ValueError(f"{len(fmts)} formats for order-{arr.ndim} tensor")

        coords = np.argwhere(arr != 0)          # (nnz, d) sorted row-major
        vals = arr[tuple(coords.T)] if len(coords) else np.zeros(0)
        return FiberTree._from_sorted_coords(
            tuple(arr.shape), coords, np.asarray(vals, dtype=np.float64),
            fmts, tuple(mode_order))

    @staticmethod
    def from_coords(shape: Sequence[int], coords: np.ndarray, vals: np.ndarray,
                    formats: str | Sequence[str]) -> "FiberTree":
        """Build from (nnz, d) coordinates (need not be sorted).

        Duplicate full coordinates are representable only when some level
        is non-``unique`` (a COO fork); with all-unique level formats they
        are rejected with a ``ValueError``.
        """
        coords = np.asarray(coords).reshape(-1, len(shape))
        vals = np.asarray(vals, dtype=np.float64)
        key = np.lexsort(coords.T[::-1])
        coords, vals = coords[key], vals[key]
        fmts = [_FORMAT_ABBREV[f] for f in formats]
        return FiberTree._from_sorted_coords(tuple(shape), coords, vals, fmts,
                                             tuple(range(len(shape))))

    @staticmethod
    def _from_sorted_coords(shape, coords, vals, fmts, mode_order) -> "FiberTree":
        d = len(shape)
        levels: List[Level] = []
        nnz = len(coords)
        if nnz > 1 and d:
            dup = bool(np.any(np.all(coords[1:] == coords[:-1], axis=1)))
            if dup and all(LEVEL_SPECS[f].unique for f in fmts):
                raise ValueError(
                    "duplicate coordinates rejected by unique level formats "
                    f"{[_ABBREV_OF[f] for f in fmts]}; use a non-unique "
                    "level (singleton 's') to keep duplicates")

        # Parent fiber id of each nonzero at each level: group rows by the
        # coordinate prefix. Dense levels densify the prefix space.
        # We iterate top-down, tracking the set of fibers (unique prefixes).
        parent_ids = np.zeros(nnz, dtype=np.int64)   # fiber index per nonzero
        num_parents = 1
        for lvl in range(d):
            fmt = fmts[lvl]
            dim = shape[lvl]
            c = coords[:, lvl] if nnz else np.zeros(0, dtype=np.int64)
            if fmt == DENSE:
                levels.append(Level(format=DENSE, dim=dim))
                parent_ids = parent_ids * dim + c
                num_parents = num_parents * dim
            elif fmt in (COMPRESSED, HASHED):
                # fibers keyed by (parent_id); storage sorted within — a
                # hashed level keeps canonical sorted backing storage and
                # applies its slot order at iteration time (``fiber``)
                seg = np.zeros(num_parents + 1, dtype=np.int64)
                if nnz:
                    # unique (parent, coord) pairs are the stored entries
                    pair_key = parent_ids * (dim + 1) + c
                    uniq, inv = np.unique(pair_key, return_inverse=True)
                    up = uniq // (dim + 1)
                    uc = uniq % (dim + 1)
                    counts = np.bincount(up, minlength=num_parents)
                    seg[1:] = np.cumsum(counts)
                    levels.append(Level(format=fmt, dim=dim,
                                        seg=seg, crd=uc.astype(np.int64)))
                    parent_ids = inv.astype(np.int64)
                    num_parents = len(uniq)
                else:
                    levels.append(Level(format=fmt, dim=dim, seg=seg,
                                        crd=np.zeros(0, dtype=np.int64)))
                    num_parents = 0
            elif fmt == SINGLETON:
                # COO level: one entry per nonzero path, duplicates across
                # siblings kept (non-unique). Rows are sorted, so entries
                # stay in (parent, coordinate) order.
                seg = np.zeros(num_parents + 1, dtype=np.int64)
                if nnz:
                    counts = np.bincount(parent_ids, minlength=num_parents)
                    seg[1:] = np.cumsum(counts)
                    levels.append(Level(format=SINGLETON, dim=dim, seg=seg,
                                        crd=c.astype(np.int64)))
                    parent_ids = np.arange(nnz, dtype=np.int64)
                    num_parents = nnz
                else:
                    levels.append(Level(format=SINGLETON, dim=dim, seg=seg,
                                        crd=np.zeros(0, dtype=np.int64)))
                    num_parents = 0
            elif fmt in (BITVECTOR, BITMAP):
                nwords = -(-dim // BV_WIDTH)
                words = np.zeros((num_parents, nwords), dtype=np.uint64)
                if nnz:
                    pair_key = parent_ids * (dim + 1) + c
                    uniq, inv = np.unique(pair_key, return_inverse=True)
                    up = (uniq // (dim + 1)).astype(np.int64)
                    uc = (uniq % (dim + 1)).astype(np.int64)
                    for p, cc in zip(up, uc):
                        words[p, cc // BV_WIDTH] |= np.uint64(1 << (cc % BV_WIDTH))
                    levels.append(Level(format=fmt, dim=dim, words=words))
                    parent_ids = inv.astype(np.int64)
                    num_parents = len(uniq)
                else:
                    levels.append(Level(format=fmt, dim=dim, words=words))
                    num_parents = 0
            else:
                raise ValueError(fmt)

        # Leaf values: one per surviving (deepest-level) position. For dense
        # trailing levels the value array is densified with explicit zeros.
        if all(f != DENSE for f in fmts):
            out_vals = vals
        else:
            out_vals = np.zeros(max(num_parents, 0))
            if nnz:
                out_vals[parent_ids] = vals
        return FiberTree(shape=tuple(shape), levels=levels, vals=out_vals,
                         mode_order=mode_order)

    # -- conversions ---------------------------------------------------------
    def to_dense(self) -> np.ndarray:
        """Expand back to a dense array in the ORIGINAL (pre-mode-order) axes."""
        if self.order == 0:
            return np.asarray(self.vals[0])
        out = np.zeros(tuple(self.shape))
        for coord, v in self.items():
            out[coord] += v
        inv = np.argsort(self.mode_order)
        # self.shape is in storage order; undo the transpose
        return np.transpose(out, inv)

    def convert(self, formats: str | Sequence[str],
                merge_duplicates: bool = False) -> "FiberTree":
        """Re-lay this tensor under new level formats, bit-identically.

        Stored positions and their float64 values are carried over exactly
        (a round trip like c→s(COO)→c reproduces the original arrays bit
        for bit). ``merge_duplicates`` sums values at equal coordinates —
        the non-unique → unique direction; without it, duplicates from a
        singleton source are rejected by unique targets (``from_coords``
        semantics).

        >>> t = FiberTree.from_dense(np.array([[1., 0.], [2., 3.]]), "cc")
        >>> coo = t.convert("ss")
        >>> back = coo.convert("cc")
        >>> bool((back.levels[1].crd == t.levels[1].crd).all())
        True
        """
        if self.order == 0:
            return FiberTree(shape=(), levels=[], vals=self.vals.copy())
        fmts = [_FORMAT_ABBREV[f] for f in formats]
        if len(fmts) != self.order:
            raise ValueError(f"{len(fmts)} formats for order-{self.order}")
        coords, vals = [], []
        for cpath, v in self.items():
            coords.append(cpath)
            vals.append(v)
        coords = np.asarray(coords, dtype=np.int64).reshape(-1, self.order)
        vals = np.asarray(vals, dtype=np.float64)
        key = np.lexsort(coords.T[::-1])
        coords, vals = coords[key], vals[key]
        if merge_duplicates and len(coords) > 1:
            same = np.all(coords[1:] == coords[:-1], axis=1)
            group = np.concatenate([[0], np.cumsum(~same)])
            keep = np.concatenate([[True], ~same])
            merged_vals = np.bincount(group, weights=vals)
            coords, vals = coords[keep], merged_vals
        return FiberTree._from_sorted_coords(self.shape, coords, vals, fmts,
                                             self.mode_order)

    def items(self):
        """Yield ((c0, c1, ...), value) for every stored position.

        Iteration follows each level's native order (hash-slot order for
        hashed levels); duplicates of non-unique levels appear once per
        stored path.
        """
        def rec(lvl: int, ref: int, prefix: tuple):
            if lvl == self.order:
                yield prefix, float(self.vals[ref])
                return
            crds, refs = self.levels[lvl].fiber(ref)
            for c, r in zip(crds, refs):
                yield from rec(lvl + 1, int(r), prefix + (int(c),))
        yield from rec(0, 0, ())

    def root_fibers(self) -> int:
        return 1


def canonical_formats(ft: FiberTree) -> str:
    """Engine-native target formats: dense stays dense, the rest compress."""
    return "".join("d" if lv.format == DENSE else "c" for lv in ft.levels)


def canonical_tree(ft: FiberTree) -> FiberTree:
    """Canonicalize a tree to engine-native d/c levels.

    Trees that are already all-d/c pass through untouched. Unique levels
    (hashed, bitmap, bitvector) convert per-level via
    ``coord_ops.convert_level`` WITHOUT touching the value array (their
    storage is already in canonical child order, so the result is
    bit-identical). Trees with non-unique (singleton) levels need a whole
    -tree rebuild: duplicates at equal coordinates merge by summation,
    matching ``to_dense`` semantics.
    """
    if all(lv.format in (DENSE, COMPRESSED) for lv in ft.levels):
        return ft
    tgt = canonical_formats(ft)
    if any(not lv.spec.unique for lv in ft.levels):
        return ft.convert(tgt, merge_duplicates=True)
    from . import coord_ops as co
    levels: List[Level] = []
    num_parents = 1
    for lv in ft.levels:
        nl = co.convert_level(lv, num_parents)
        levels.append(nl)
        num_parents = (num_parents * nl.dim if nl.format == DENSE
                       else len(nl.crd))
    return FiberTree(shape=ft.shape, levels=levels, vals=ft.vals,
                     mode_order=ft.mode_order)
