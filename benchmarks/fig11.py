"""Fig. 11: fused vs unfused SDDMM, K in {1, 10, 100}.

X(i,j) = B(i,j) * C(i,k) * D(j,k), I=J=250, B 95% sparse, C/D dense.
Unfused (the factorized fixed-function pipeline) materializes the whole
dense product T = C @ D^T (I*J*K work) and then samples it; the fused SAM
dataflow only computes at B's nonzeros (nnz_B * K). The locate variant
(§4.2) additionally skips co-iteration when finding the sampled (i, j)
positions; its advantage fades as K grows (iteration cost of the dense k
dimension dominates) — both paper claims are checked.
"""
from __future__ import annotations

from .common import run_expr, uniform_sparse

I, J = 250, 250


def run(emit, smoke: bool = False):
    ok = True
    prev_ratio = None
    i, j = (64, 64) if smoke else (I, J)
    for K in (1, 10) if smoke else (1, 10, 100):
        B = uniform_sparse((i, j), 0.05)
        C = uniform_sparse((i, K), 1.0)
        D = uniform_sparse((j, K), 1.0)
        dims = {"i": i, "j": j, "k": K}

        fused, _ = run_expr("X(i,j) = B(i,j) * C(i,k) * D(j,k)",
                            {"B": "cc", "C": "dd", "D": "dd"}, "ijk",
                            {"B": B, "C": C, "D": D}, dims)
        fused_loc, _ = run_expr(
            "X(i,j) = B(i,j) * C(i,k) * D(j,k)",
            {"B": "cc", "C": "dd", "D": "dd"}, "ijk",
            {"B": B, "C": C, "D": D}, dims,
            locate={("C", "i"), ("D", "j")})
        # unfused: dense T = C@D^T, then sample by B
        stage1, _ = run_expr("T(i,j) = C(i,k) * D(j,k)",
                             {"C": "dd", "D": "dd", "T": "dd"}, "ijk",
                             {"C": C, "D": D}, dims)
        T = stage1.outputs["T"].to_dense()
        stage2, _ = run_expr("X(i,j) = B(i,j) * T(i,j)",
                             {"B": "cc", "T": "dd"}, "ij",
                             {"B": B, "T": T}, dims,
                             locate={("T", "j")})
        unfused = stage1.cycles + stage2.cycles
        emit(f"fig11,K={K},fused,{fused.cycles}")
        emit(f"fig11,K={K},fused_locate,{fused_loc.cycles}")
        emit(f"fig11,K={K},unfused,{unfused}")
        ok &= unfused > fused.cycles            # fusion wins
        ok &= fused_loc.cycles <= fused.cycles  # locate never hurts
        ratio = fused.cycles / fused_loc.cycles
        if prev_ratio is not None:
            ok &= ratio <= prev_ratio * 1.5     # locate advantage fades w/ K
        prev_ratio = ratio
    emit(f"fig11/summary,fusion_wins_and_locate_fades,{ok}")
    return ok
