"""Pallas kernels vs. pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(3)


def random_bsr(n_brow, n_bcol, bs, density, dtype):
    mask = RNG.random((n_brow, n_bcol)) < density
    rows, cols = np.nonzero(mask)
    if len(rows) == 0:
        rows, cols = np.array([0]), np.array([0])
    blocks = RNG.normal(size=(len(rows), bs, bs)).astype(dtype)
    return rows, cols, blocks


@pytest.mark.parametrize("bs,n_brow,n_bcol,n,dtype", [
    (8, 4, 3, 128, jnp.float32),
    (16, 3, 5, 256, jnp.float32),
    (8, 2, 2, 128, jnp.bfloat16),
    (32, 5, 4, 128, jnp.float32),
])
def test_spmm_bsr_matches_ref(bs, n_brow, n_bcol, n, dtype):
    rows, cols, blocks = random_bsr(n_brow, n_bcol, bs, 0.5, np.float32)
    blocks = blocks.astype(dtype)
    c = jnp.asarray(RNG.normal(size=(n_bcol * bs, n)), dtype)
    blk_map, col_idx, blocks_p = ops.bsr_from_block_coords(
        rows, cols, np.asarray(blocks), n_brow)
    got = ops.spmm_bsr(blk_map, col_idx, blocks_p, c, n_tile=128,
                       interpret=True)
    want = ref.spmm_bsr_ref(jnp.asarray(blk_map), jnp.asarray(col_idx),
                            jnp.asarray(blocks_p), c)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bs,m_blk,n_blk,k,dtype", [
    (8, 3, 4, 128, jnp.float32),
    (16, 2, 2, 256, jnp.float32),
    (8, 4, 3, 128, jnp.bfloat16),
])
def test_sddmm_bsr_matches_ref(bs, m_blk, n_blk, k, dtype):
    mask = RNG.random((m_blk, n_blk)) < 0.6
    rows, cols = np.nonzero(mask)
    if len(rows) == 0:
        rows, cols = np.array([0]), np.array([0])
    a = jnp.asarray(RNG.normal(size=(m_blk * bs, k)), dtype)
    b = jnp.asarray(RNG.normal(size=(n_blk * bs, k)), dtype)
    got = ops.sddmm_bsr(rows.astype(np.int32), cols.astype(np.int32), a, b,
                        bs, k_tile=128, interpret=True)
    want = ref.sddmm_bsr_ref(rows, cols, a, b, bs)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bq,s,d,causal,dtype", [
    (8, 64, 32, False, jnp.float32),
    (8, 64, 32, True, jnp.float32),
    (16, 128, 64, True, jnp.float32),
    (8, 64, 32, True, jnp.bfloat16),
])
def test_bsr_attention_matches_ref(bq, s, d, causal, dtype):
    bh = 2
    n_blk = s // bq
    q = jnp.asarray(RNG.normal(size=(bh, s, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(bh, s, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(bh, s, d)), dtype)
    # random block mask incl. diagonal (so no fully-masked rows w/ causal)
    kv_idx = np.full((n_blk, n_blk), n_blk, dtype=np.int32)
    for qi in range(n_blk):
        picks = sorted(set([qi] + list(
            RNG.choice(qi + 1 if causal else n_blk,
                       size=min(2, qi + 1 if causal else n_blk),
                       replace=False))))
        kv_idx[qi, :len(picks)] = picks
    got = ops.bsr_flash_attention(q, k, v, jnp.asarray(kv_idx), bq=bq,
                                  bkv=bq, causal=causal, interpret=True)
    want = ref.bsr_flash_attention_ref(q, k, v, kv_idx, bq=bq, bkv=bq,
                                       causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_sliding_window_idx_long_context():
    idx = ops.sliding_window_kv_idx(8, 8, 3)
    assert idx.shape == (8, 3)
    assert idx[0].tolist() == [0, 8, 8]
    assert idx[5].tolist() == [3, 4, 5]


@pytest.mark.parametrize("n,d,s,dtype", [
    (100, 16, 7, jnp.float32),
    (1024, 128, 64, jnp.float32),
    (513, 200, 9, jnp.float32),
    (256, 64, 8, jnp.bfloat16),
])
def test_segment_reduce_matches_ref(n, d, s, dtype):
    vals = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    ids = jnp.asarray(RNG.integers(0, s, n), jnp.int32)
    got = ops.segment_reduce(vals, ids, num_segments=s, t_tile=256,
                             interpret=True)
    want = ref.segment_reduce_ref(vals, ids, num_segments=s)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_segment_reduce_is_sam_reducer():
    """The kernel is the Def-3.7 reducer: dedup + sum of repeated coords."""
    from repro.core import coord_ops as co
    keys = jnp.asarray([3, 1, 3, 0, 1, 3], jnp.int64)
    vals = jnp.asarray([1., 2., 3., 4., 5., 6.])
    valid = jnp.ones(6, bool)
    uk, uv, uvalid = co.sorted_segment_reduce(keys, vals, valid, 8)
    got = {int(k): float(v) for k, v, ok in zip(uk, uv, uvalid) if ok}
    assert got == {0: 4.0, 1: 7.0, 3: 10.0}
    # same result through the Pallas kernel path
    out = ops.segment_reduce(vals[:, None], keys.astype(jnp.int32),
                             num_segments=4, interpret=True)
    np.testing.assert_allclose(np.asarray(out)[:, 0], [4., 7., 0., 10.])
