"""The documentation executes as written: every ```python code block in
docs/SCHEDULING.md, docs/PROGRAMS.md and README.md runs top-to-bottom,
so the guides' snippets and the quickstart cannot rot. (Docstring
examples are guarded separately by CI's ``pytest --doctest-modules``
step over the public scheduling/compile modules.)"""
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _python_blocks(path: pathlib.Path):
    text = path.read_text()
    return re.findall(r"```python\n(.*?)```", text, re.S)


@pytest.mark.parametrize("doc", ["docs/SCHEDULING.md", "docs/PROGRAMS.md",
                                 "README.md"])
def test_markdown_snippets_execute(doc, tmp_path, monkeypatch):
    monkeypatch.setenv("SAM_SCHEDULE_CACHE",
                       str(tmp_path / "schedules.json"))
    blocks = _python_blocks(ROOT / doc)
    assert blocks, f"{doc} has no python snippets"
    ns = {}
    for i, block in enumerate(blocks):
        code = compile(block, f"{doc}[block {i}]", "exec")
        exec(code, ns)  # blocks build on each other, as a reader would run them
