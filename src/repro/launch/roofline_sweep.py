import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Probe-based roofline sweep (§Roofline): every (arch x shape) cell on the
single-pod 16x16 mesh, trip-count-corrected via layer probes.

    PYTHONPATH=src python -m repro.launch.roofline_sweep --json roofline.json

``--sam`` switches to the SAM (format x schedule x hardware) sweep: the
autoscheduler searches the joint format+schedule space once, then every
surviving candidate is re-costed under every ``simulator.HW_PRESETS``
hardware model (or ``--hw pe8,bw4``) — one command produces the full
modeled-cycles grid, written incrementally to ``--json``:

    PYTHONPATH=src python -m repro.launch.roofline_sweep \
        --sam "X(i,j) = B(i,j) * C(i,j)" --sam-dims i=128,j=128 \
        --sam-density 0.25 --json sam_roofline.json
"""
import argparse
import json
import time
import traceback


def _parse_kv(text, cast=int):
    return {k: cast(v) for k, v in
            (item.split("=") for item in text.split(","))} if text else {}


def sam_sweep(args) -> None:
    """(format x schedule x hardware) sweep over the SAM cost model."""
    from ..core.autoschedule import (FORMAT_CHOICES, resolve_densities,
                                     search, synthetic_operands)
    from ..core.einsum import parse
    from ..core.schedule import Format
    from ..core.simulator import HW_PRESETS, simulate_expr

    dims = _parse_kv(args.sam_dims)
    fmt = Format(_parse_kv(args.sam_formats, cast=str))
    assign = parse(args.sam)
    densities = resolve_densities(assign, args.sam_density)
    arrays = synthetic_operands(assign, dims, densities)
    hw_names = args.hw.split(",") if args.hw else sorted(HW_PRESETS)
    rep = search(assign, fmt, dims, arrays=arrays, device_count=1,
                 top_k=args.top_k, format_choices=FORMAT_CHOICES)
    results = []
    for cand in rep.candidates:
        cfmt = cand.spec.format(fmt)
        for hw in hw_names:
            t0 = time.time()
            res = simulate_expr(assign, cfmt, cand.schedule, arrays, dims,
                                hw=HW_PRESETS[hw])
            results.append({
                "expr": args.sam, "schedule": cand.spec.key(),
                "formats": dict(cand.spec.formats), "hw": hw,
                "cycles": int(res.cycles), "sweep_s": time.time() - t0})
            print(f"[sam-roofline] {cand.spec.key()} x {hw}: "
                  f"{res.cycles} cycles", flush=True)
            with open(args.json + ".tmp", "w") as f:
                json.dump(results, f, indent=1)
            os.replace(args.json + ".tmp", args.json)
    print(f"[sam-roofline] wrote {len(results)} cells to {args.json}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="roofline_baseline.json")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--sam", default=None,
                    help="SAM einsum: sweep (format x schedule x hardware)")
    ap.add_argument("--sam-dims", default="",
                    help="index extents, e.g. i=128,j=128")
    ap.add_argument("--sam-formats", default="",
                    help="baseline formats, e.g. B=cc,C=cc")
    ap.add_argument("--sam-density", type=float, default=0.1)
    ap.add_argument("--hw", default=None,
                    help="comma-joined simulator.HW_PRESETS names (default all)")
    ap.add_argument("--top-k", type=int, default=8)
    args = ap.parse_args(argv)

    if args.sam:
        sam_sweep(args)
        return

    from ..configs import SHAPES, get_config, list_archs, supports_shape
    from ..roofline.probe import probe_cell

    results = []
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            if not supports_shape(cfg, shape):
                results.append({"arch": arch, "shape": shape,
                                "skipped": True})
                continue
            t0 = time.time()
            try:
                r = probe_cell(arch, shape, remat=args.remat)
                r["probe_s"] = time.time() - t0
                results.append(r)
                print(f"[roofline] {arch} x {shape}: "
                      f"comp={r['t_compute']:.3e} mem={r['t_memory']:.3e} "
                      f"coll={r['t_collective']:.3e} "
                      f"bneck={r['bottleneck']} frac={r['roofline_fraction']:.3f} "
                      f"useful={r['useful_flop_ratio']:.2f} "
                      f"({r['probe_s']:.0f}s)", flush=True)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape,
                                "error": str(e)})
            with open(args.json + ".tmp", "w") as f:
                json.dump(results, f, indent=1)
            os.replace(args.json + ".tmp", args.json)
    print(f"[roofline] wrote {len(results)} records to {args.json}")


if __name__ == "__main__":
    main()
