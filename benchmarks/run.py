"""Benchmark harness entry: one module per paper table/figure + the LM
integration bench. ``PYTHONPATH=src python -m benchmarks.run [names...]``

Per-row output is CSV; each module also gets a summary row
``name,us_per_call,derived`` where derived is the pass/fail of the paper's
qualitative claim for that table/figure.

``--smoke`` runs every benchmark at its minimum size (CI's bit-rot guard:
the claims are still checked, just on small inputs). Benchmarks whose
normal size already IS the minimum meaningful one (exact Table-1/2 counts,
the fig13/fig15 model sweeps) take no smoke parameter and run as-is.
"""
from __future__ import annotations

import inspect
import sys
import time


def main() -> None:
    from . import (autotune, compiled_cache, dist_tiles, fig11, fig12,
                   fig13, fig14, fig15, formats, kernels, model_blocks,
                   moe_dispatch, program_fusion, serving, split_scaling,
                   table1, table2, tiled_oob)
    benches = {
        "kernels": kernels.run,
        "table1": table1.run, "table2": table2.run,
        "fig11": fig11.run, "fig12": fig12.run, "fig13": fig13.run,
        "fig14": fig14.run, "fig15": fig15.run,
        "moe_dispatch": moe_dispatch.run,
        "compiled_cache": compiled_cache.run,
        "split_scaling": split_scaling.run,
        "autotune": autotune.run,
        "formats": formats.run,
        "program_fusion": program_fusion.run,
        "model_blocks": model_blocks.run,
        "tiled_oob": tiled_oob.run,
        "serving": serving.run,
        "dist_tiles": dist_tiles.run,
    }
    args = sys.argv[1:]
    smoke = "--smoke" in args
    names = [a for a in args if a != "--smoke"] or list(benches)
    rows = []
    failed = []
    for name in names:
        t0 = time.perf_counter()
        try:
            fn = benches[name]
            kw = ({"smoke": True}
                  if smoke and "smoke" in inspect.signature(fn).parameters
                  else {})
            ok = fn(lambda s: print(s, flush=True), **kw)
        except Exception:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            ok = False
        us = (time.perf_counter() - t0) * 1e6
        rows.append(f"{name},{us:.0f},{'pass' if ok else 'FAIL'}")
        if not ok:
            failed.append(name)
    print("\n# name,us_per_call,derived")
    for r in rows:
        print(r)
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
